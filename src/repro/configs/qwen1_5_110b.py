"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.

QKV bias [hf:Qwen/Qwen1.5 family]. Largest dense arch in the pool — the
Boolean int8 weight story (vs bf16/fp32+Adam latents) is what makes its
*training* state fit one v5e pod (see DESIGN.md §6).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152_064,
    qkv_bias=True,
)

SMOKE = CONFIG.scaled(
    name="qwen1.5-110b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
    vocab_size=128, attn_chunk=64, remat=False,
)
