"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, MoE 64 experts top-6 [hf:moonshotai/Moonlight-16B-A3B].

Boolean expert weights (int8) cut the dominant expert memory 4× vs bf16 —
the flagship B⊕LD MoE integration. Routers stay FP.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    n_experts=64,
    top_k=6,
    # moe_impl: einsum default (paper-era GShard). §Perf measured scatter
    # better on SINGLE-POD cells (train compute −95 %, prefill mem −65 %)
    # but worse on multi-pod memory — select per cell via
    # --variant '{"moe_impl": "scatter"}' (EXPERIMENTS.md §Perf #1/#10/#15).
)

SMOKE = CONFIG.scaled(
    name="moonshot-v1-16b-a3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab_size=128, n_experts=8, top_k=2, attn_chunk=64, remat=False,
)
