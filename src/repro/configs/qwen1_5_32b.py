"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.

QKV bias [hf:Qwen/Qwen1.5 family].
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152_064,
    qkv_bias=True,
)

SMOKE = CONFIG.scaled(
    name="qwen1.5-32b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=128, attn_chunk=64, remat=False,
)
