"""Paper's own: Boolean BERT-base (§4.3 BERT fine-tuning / Table 7).

BERT-base geometry (12L, 768, 12H, 3072, vocab 30522). NOTE: the framework's
unified LM is causal-decoder-shaped; for the GLUE-analog benchmark
(benchmarks/table7_bert_glue.py) a bidirectional pooling head is built from
the same Boolean blocks. This config exists so the paper's own transformer
is a first-class --arch selection.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="bold-bert",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=30_522,
)

SMOKE = CONFIG.scaled(
    name="bold-bert-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=128, attn_chunk=64, remat=False,
)
