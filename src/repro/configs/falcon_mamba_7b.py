"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024, state=16.

Mamba-1 architecture [arXiv:2410.05355]: d_inner = 2·d_model = 8192,
dt_rank = d_model/16 = 256, conv width 4. Attention-free ⇒ long_500k
eligible (O(1) decode state). B⊕LD applies to the in/x/dt/out projections;
the selective-scan recurrence stays FP (DESIGN.md §Arch-applicability).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,          # unused (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    conv_width=4,
    long_context=True,
)

SMOKE = CONFIG.scaled(
    name="falcon-mamba-7b-smoke",
    n_layers=2, d_model=64, d_inner=128, dt_rank=8, ssm_state=4,
    vocab_size=128, remat=False,
)
