"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave
[arXiv:2403.19887].

Scanned as 9 groups of 8 blocks: in-group index 4 is attention (jamba's
attn_layer_offset), the rest Mamba; MoE FFN on odd in-group indices,
dense FFN on even (jamba's every-other-layer MoE). Hybrid ⇒ long_500k
eligible: 9 attention layers flash-decode over a sharded 500k KV cache,
everything else carries O(1) SSM state.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65_536,
    n_experts=16,
    top_k=2,
    dense_ff=24576,
    ssm_state=16,
    d_inner=16384,
    dt_rank=512,
    conv_width=4,
    group_size=8,
    attn_index=4,
    long_context=True,
)

SMOKE = CONFIG.scaled(
    name="jamba-1.5-large-398b-smoke",
    n_layers=8, group_size=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, dense_ff=128, vocab_size=128, n_experts=4, top_k=2,
    d_inner=128, dt_rank=8, ssm_state=4, attn_index=4, attn_chunk=64,
    remat=False,
)
