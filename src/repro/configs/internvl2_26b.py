"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821].

This entry specifies the InternLM2-20B transformer BACKBONE only; the
InternViT vision frontend is a STUB — ``input_specs`` provides precomputed
patch embeddings (B, S, d_model). Vocab padded 92553 -> 92672 for clean
model-axis sharding (padded logits masked in the loss).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92_553,
    frontend="embeddings",
)

SMOKE = CONFIG.scaled(
    name="internvl2-26b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=130, attn_chunk=64, remat=False,
)
