"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

Decoder-only transformer over EnCodec tokens [arXiv:2306.05284]. The EnCodec
frontend is a STUB: ``input_specs`` provides precomputed frame embeddings
(B, S, d_model); the output head maps to the 2048-entry codebook.
Deviation: RoPE replaces MusicGen's sinusoidal positions (trained from
scratch; documented in DESIGN.md).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend="embeddings",
)

SMOKE = CONFIG.scaled(
    name="musicgen-medium-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=128, attn_chunk=64, remat=False,
)
