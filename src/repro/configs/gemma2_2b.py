"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local(4096-window)+global alternating attention, attn/final logit
softcapping (50/30), head_dim 256 [arXiv:2408.00118]. Scanned as 13 groups
of (local, global) pairs.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    alt_local_global=True,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    group_size=2,
)

SMOKE = CONFIG.scaled(
    name="gemma2-2b-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=128, sliding_window=32, attn_chunk=64, remat=False,
)
