"""Architecture registry: the 10 assigned archs + the paper's own models.

Each module exposes ``CONFIG`` (the exact published configuration — exercised
only via the dry-run, never allocated on CPU) and ``SMOKE`` (a reduced
same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS: List[str] = [
    "musicgen-medium",
    "gemma2-2b",
    "qwen1.5-32b",
    "qwen2.5-14b",
    "qwen1.5-110b",
    "falcon-mamba-7b",
    "moonshot-v1-16b-a3b",
    "arctic-480b",
    "jamba-1.5-large-398b",
    "internvl2-26b",
]

PAPER_IDS: List[str] = ["bold-bert", "bold-vgg-small"]

_MODULES: Dict[str, str] = {
    "musicgen-medium": "musicgen_medium",
    "gemma2-2b": "gemma2_2b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen1.5-110b": "qwen1_5_110b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "arctic-480b": "arctic_480b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "internvl2-26b": "internvl2_26b",
    "bold-bert": "bold_bert",
    "bold-vgg-small": "bold_vgg_small",
}


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _mod(arch_id).CONFIG


def get_smoke(arch_id: str):
    return _mod(arch_id).SMOKE
