"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual FFN
[hf:Snowflake/snowflake-arctic-base].

Largest weight volume in the pool (~482B params). With B⊕LD int8 Boolean
experts + bf16 accumulators the full *training* state is ~5.7 GB/chip on a
256-chip pod; the BNN/fp32-latent equivalent would need ~23 GB/chip and not
fit (DESIGN.md §6).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    dense_ff=4864,
    # moe_impl: einsum default; scatter per cell (§Perf #6/#15).
)

SMOKE = CONFIG.scaled(
    name="arctic-480b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, dense_ff=64,
    vocab_size=128, n_experts=8, top_k=2, attn_chunk=64, remat=False,
)
