"""Paper's own: VGG-SMALL on CIFAR10 (§4.1, Tables 2/6/9).

A CNN, not an LM — consumed by the vision substrate
(repro/vision/vgg.py) and the Table-2/6 benchmarks; not part of the LM
dry-run grid.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class VGGConfig:
    name: str = "bold-vgg-small"
    # (channels, n_convs) per stage, 2x2 maxpool between stages — VGG-SMALL.
    stages: Tuple[Tuple[int, int], ...] = ((128, 2), (256, 2), (512, 2))
    input_hw: int = 32
    in_channels: int = 3
    n_classes: int = 10
    fc_dim: int = 1024
    boolean: bool = True
    with_bn: bool = False       # paper evaluates both (Table 2)

    def scaled(self, **kw):
        return dataclasses.replace(self, **kw)


CONFIG = VGGConfig()

SMOKE = CONFIG.scaled(name="bold-vgg-small-smoke",
                      stages=((16, 1), (32, 1)), input_hw=16, fc_dim=64)
