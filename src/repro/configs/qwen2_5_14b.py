"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

GQA + QKV bias, 1M rope theta [hf:Qwen/Qwen2.5 family].
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(
    name="qwen2.5-14b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=128, attn_chunk=64, remat=False,
)
