"""Deterministic, resumable token pipeline.

Fault-tolerance contract: a pipeline is a pure function of (seed, step) —
after preemption/restart at step k, batch k is bit-identical, with no
iterator state to checkpoint beyond the step counter. Shards by
(process_index, num_processes) for multi-host runs; on a single host it
yields global batches that pjit shards over ("pod","data").

Two backends:
  SyntheticLM     — PRNG token stream with a learnable structure (Markov-ish
                    mixture so models can actually reduce loss).
  BinTokenDataset — memory-mapped flat .bin of token ids (uint16/uint32),
                    the standard packed-corpus format.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2          # tokens depend on a hash of the last `order`

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # structured stream: next token = hash(prev tokens) + noise
        toks = np.zeros((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.random((B, S))
        rand_tok = rng.integers(0, V, (B, S))
        for t in range(1, S + 1):
            det = (toks[:, t - 1] * 31 + (toks[:, t - 2] if t >= 2 else 0)
                   * 17 + 7) % V
            toks[:, t] = np.where(noise[:, t - 1] < 0.8, det,
                                  rand_tok[:, t - 1])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class BinTokenDataset:
    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n = len(self._data) - (self.seq_len + 1)
        if self._n <= 0:
            raise ValueError(f"{self.path}: too short for seq_len")

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        starts = rng.integers(0, self._n, self.global_batch)
        rows = np.stack([np.asarray(self._data[s:s + self.seq_len + 1])
                         for s in starts]).astype(np.int32)
        rows = np.clip(rows, 0, self.vocab_size - 1)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(cfg, seq_len: int, global_batch: int,
                  path: Optional[str] = None, seed: int = 0):
    if path:
        return BinTokenDataset(path, cfg.vocab_size, seq_len, global_batch,
                               seed=seed)
    return SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=seed)
