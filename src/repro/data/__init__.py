from .pipeline import SyntheticLM, BinTokenDataset, make_pipeline
