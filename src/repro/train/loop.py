"""Fault-tolerant training loop.

1000+-node posture (each mechanism is exercised by tests/examples at small
scale; the mechanisms are scale-free):

  * auto-restore: on start, the latest committed checkpoint (params + opt
    state + step) is restored and the data pipeline resumes at that step
    (batches are pure functions of step — no iterator state).
  * async keep-N checkpointing every `ckpt_every` steps (atomic rename
    commit; a crash mid-write is invisible to restore).
  * preemption: SIGTERM/SIGINT trigger one final synchronous checkpoint
    before exit (the SLURM/Borg eviction contract).
  * straggler watchdog: per-step wall time is tracked against a rolling
    median; steps slower than `straggler_factor`× the median are logged
    with their step index — on real fleets this feeds the scheduler's
    hot-standby replacement. (Single-process here, so detection only.)
  * elastic: restore re-shards full-array checkpoints onto whatever mesh
    is live (see checkpoint/manager.py).
"""
from __future__ import annotations

import signal
import statistics
import time
from pathlib import Path
from typing import Callable, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class TrainLoop:
    def __init__(self, train_step: Callable, params, opt_state,
                 pipeline, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 100, straggler_factor: float = 2.0,
                 log_every: int = 10, shardings=None):
        self.train_step = train_step
        self.params, self.opt_state = params, opt_state
        self.pipeline = pipeline
        self.step = 0
        self.ckpt = CheckpointManager(ckpt_dir, ckpt_every) if ckpt_dir else None
        self.straggler_factor = straggler_factor
        self.log_every = log_every
        self.shardings = shardings
        self.step_times: list = []
        self.stragglers: list = []
        self.history: list = []
        self._preempted = False

        if self.ckpt and self.ckpt.latest_step() is not None:
            state = {"params": self.params, "opt": self.opt_state}
            restored, step = self.ckpt.restore_latest(state, shardings)
            self.params, self.opt_state = restored["params"], restored["opt"]
            self.step = step
            print(f"[loop] restored checkpoint at step {step}", flush=True)

    def _handle_preemption(self, signum, frame):
        print(f"[loop] signal {signum}: checkpoint-and-exit", flush=True)
        self._preempted = True

    def run(self, num_steps: int, install_signal_handlers: bool = True):
        if install_signal_handlers:
            try:
                signal.signal(signal.SIGTERM, self._handle_preemption)
                signal.signal(signal.SIGINT, self._handle_preemption)
            except ValueError:
                pass  # non-main thread (tests)

        target = self.step + num_steps
        while self.step < target and not self._preempted:
            batch = self.pipeline.batch_at(self.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])         # blocks → true step time
            dt = time.time() - t0
            self.step += 1
            self.step_times.append(dt)
            self.history.append(loss)

            if len(self.step_times) >= 5:
                med = statistics.median(self.step_times[-50:])
                if dt > self.straggler_factor * med and self.step > 5:
                    self.stragglers.append((self.step, dt, med))
                    print(f"[loop] straggler: step {self.step} took "
                          f"{dt:.2f}s (median {med:.2f}s)", flush=True)

            if self.step % self.log_every == 0:
                flips = float(metrics.get("flips", 0.0))
                print(f"[loop] step {self.step} loss {loss:.4f} "
                      f"flips {flips:.0f} {dt*1000:.0f}ms", flush=True)
            if self.ckpt:
                self.ckpt.maybe_save(self.step,
                                     {"params": self.params,
                                      "opt": self.opt_state})

        if self.ckpt:
            self.ckpt.save_now(self.step, {"params": self.params,
                                           "opt": self.opt_state})
        return self.history
