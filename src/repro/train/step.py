"""Train / serve step factories (pjit-ready pure functions).

``train_step`` implements the paper's full recipe at pod scale:
  1. view int8 Boolean params as ±1 bf16 for one differentiation (no
     persistent FP latents — DESIGN.md §2),
  2. microbatched gradient accumulation (lax.scan) so per-device activation
     memory is one microbatch; vote counts accumulate in fp32 — summing
     votes across microbatches IS the paper's Eq-7 batch aggregation,
  3. Boolean flip-rule update for int8 leaves + Adam for FP leaves.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core.optimizer import Optimizer, is_boolean_leaf
from repro.models import ModelConfig, lm_decode_step, lm_loss, lm_prefill
from repro.models.modules import constrain


def bool_view(params, dtype=jnp.bfloat16):
    """int8 ±1 leaves -> float view (bitwise-determined, transient)."""
    return jax.tree.map(
        lambda p: p.astype(dtype) if is_boolean_leaf(p) else p, params)


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    microbatches: int = 1,
                    grad_accum_dtype=jnp.float32,
                    grad_shardings=None):
    """grad_shardings: optional tree of NamedSharding matching params — the
    per-microbatch grads are constrained to it so the DP reduction lowers
    as reduce-scatter into the FSDP shard instead of all-reduce + slice
    (§Perf: grad-RS)."""
    def loss_fn(pf, mb):
        return lm_loss(cfg, pf, mb)

    def _constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(
            lambda gi, sh: jax.lax.with_sharding_constraint(gi, sh),
            g, grad_shardings)

    def train_step(params, opt_state, batch):
        pf = bool_view(params, cfg.dtype)
        if microbatches == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(pf, batch)
            grads = _constrain_grads(grads)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            b_ax = cfg.batch_axes if cfg.batch_axes else None
            mbs = jax.tree.map(
                lambda x: constrain(
                    cfg,
                    x.reshape((microbatches, x.shape[0] // microbatches)
                              + x.shape[1:]),
                    P(None, b_ax, *([None] * (x.ndim - 1)))), batch)

            def mb_step(carry, mb):
                loss_acc, gacc = carry
                (loss, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(pf, mb)
                g = _constrain_grads(g)
                gacc = jax.tree.map(
                    lambda a, gi: a + gi.astype(grad_accum_dtype), gacc, g)
                # keep the accumulation CARRY sharded like the params —
                # otherwise SPMD resolves the scan carry to replicated fp32
                # (~50 GiB/device at 400B scale; §Perf iteration #12)
                gacc = _constrain_grads(gacc)
                return (loss_acc + loss, gacc), parts

            g0 = _constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_accum_dtype), pf))
            (loss_sum, grads), _ = jax.lax.scan(
                mb_step, (jnp.zeros((), jnp.float32), g0), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32),
                   "flips": _flip_total(new_opt_state)}
        return new_params, new_opt_state, metrics

    return train_step


def _flip_total(opt_state):
    flips = getattr(getattr(opt_state, "boolean", opt_state), "flips", None)
    if flips is None:
        return jnp.zeros((), jnp.float32)
    leaves = [l for l in jax.tree.leaves(flips)]
    return sum(leaves) if leaves else jnp.zeros((), jnp.float32)


def make_prefill_step(cfg: ModelConfig):
    """Serving prefill: raw int8 params (per-layer transient float views)."""
    def prefill_step(params, batch):
        return lm_prefill(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        return lm_decode_step(cfg, params, cache, tokens)
    return decode_step
