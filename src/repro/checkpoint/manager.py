"""Fault-tolerant checkpointing: async, atomic, elastic-restorable.

Layout per step:
    <dir>/step_000123.tmp/           (written)
    <dir>/step_000123/               (atomic rename on completion)
        manifest.json                (tree structure, dtypes, shapes, step)
        leaf_000000.npy ...          (row-major leaves)

Design points for 1000+-node operation:
  * ATOMIC: the rename is the commit point; a killed writer leaves only a
    .tmp dir that restore ignores and the next save garbage-collects.
  * ASYNC: device→host transfer happens at save() call; file I/O runs on a
    background thread so the train loop overlaps checkpoint writes with
    the next steps.
  * ELASTIC: leaves are saved as FULL (unsharded) arrays keyed by tree
    path; restore re-shards onto whatever mesh is live (device_put with
    the current NamedSharding) — pod counts can change across restarts.
  * BOOLEAN-COMPACT: int8 Boolean leaves are bit-packed 8:1 on disk
    (uint8 bitmaps), so a 480B-param Boolean checkpoint is ~60 GB.
  * KEEP-N: older steps pruned after a successful commit.
  * CHECKSUMMED: every leaf's on-disk bytes carry a crc32 in the
    manifest; restore verifies BEFORE deserializing and raises a typed
    ``CheckpointCorruption`` on mismatch. B⊕LD makes this non-optional:
    a flipped bit in a packed Boolean leaf is a sign flip that ``sign()``
    amplifies into confidently wrong outputs, not noise — silently-wrong
    weights are the one failure mode a restore must never have.
    (Pre-checksum checkpoints restore with a skipped verify — the
    manifest entry simply has no ``crc32`` key.)

(On real multi-host pods each host writes its addressable shards and the
manifest records the global shape; this container is single-process so
leaves are full arrays — the manifest format already carries shard info.)
"""
from __future__ import annotations

import io
import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


class CheckpointCorruption(RuntimeError):
    """A leaf's on-disk bytes fail their manifest checksum. Typed so a
    restore caller can distinguish "this checkpoint is damaged — fall
    back to an older step" from a programming error; it must NEVER be
    swallowed into a partially-restored tree."""

    def __init__(self, step: int, key: str, fname: str,
                 want: int, got: int):
        self.step = step
        self.key = key
        self.file = fname
        super().__init__(
            f"checkpoint step {step}: leaf {key!r} ({fname}) checksum "
            f"mismatch (manifest crc32={want:#010x}, bytes={got:#010x}) "
            "— refusing to deserialize corrupted weights")


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _pack_bool(arr: np.ndarray):
    bits = np.packbits((arr.reshape(-1) > 0).astype(np.uint8))
    return bits


def _unpack_bool(bits: np.ndarray, shape, size):
    vals = np.unpackbits(bits, count=size).astype(np.int8)
    return (vals * 2 - 1).reshape(shape)


def save_pytree(tree, directory: Path, step: int,
                sync: bool = False) -> threading.Thread:
    """Write a checkpoint; returns the writer thread (joined if sync)."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp"

    # device->host now (cheap, bounded); file I/O in the background.
    flat = {k: np.asarray(jax.device_get(v)) for k, v in
            _flatten(tree).items()}

    def write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i:06d}.npy"
            entry = {"file": fname, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)}
            if arr.dtype == np.int8 and arr.size and \
                    set(np.unique(arr[..., :1])) <= {-1, 1}:
                save_arr = _pack_bool(arr)
                entry["packed_boolean"] = True
            else:
                save_arr = arr
                if arr.dtype == jax.numpy.bfloat16:
                    save_arr = arr.view(np.uint16)
                    entry["bf16_as_u16"] = True
            # serialize to memory first: the crc covers the EXACT bytes
            # that land on disk (.npy header included), so any later
            # corruption — bit rot, truncation, a bad copy — is caught
            buf = io.BytesIO()
            np.save(buf, save_arr)
            raw = buf.getvalue()
            entry["crc32"] = zlib.crc32(raw) & 0xFFFFFFFF
            (tmp / fname).write_bytes(raw)
            manifest["leaves"][key] = entry
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # commit point
        _prune(directory, keep=3)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if sync:
        t.join()
    return t


def _prune(directory: Path, keep: int):
    steps = sorted(d for d in directory.glob("step_*") if d.is_dir()
                   and not d.name.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
    for d in directory.glob("step_*.tmp"):
        shutil.rmtree(d, ignore_errors=True)


def latest_step(directory: Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(d.name.split("_")[1]) for d in directory.glob("step_*")
                   if d.is_dir() and not d.name.endswith(".tmp"))
    return steps[-1] if steps else None


def restore_pytree(template, directory: Path, step: Optional[int] = None,
                   shardings=None, faults=None):
    """Restore into the structure of ``template``; re-shards onto the live
    mesh when ``shardings`` (a matching tree of NamedSharding) is given.

    Every leaf's raw bytes are checksum-verified against the manifest
    BEFORE ``np.load`` touches them; a mismatch raises
    ``CheckpointCorruption`` naming the step/leaf/file. ``faults`` (a
    ``serve.FaultInjector``) arms the ``ckpt_corrupt`` drill: when it
    fires for a leaf, one byte of the in-memory stream is flipped before
    the verify — proving the checksum walk turns disk corruption into the
    typed error rather than silently-wrong weights."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    manifest = json.loads((src / "manifest.json").read_text())

    flat_tpl = _flatten(template)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, entry in manifest["leaves"].items():
        if key not in flat_tpl:
            continue
        raw_bytes = (src / entry["file"]).read_bytes()
        if faults is not None and faults.should_fire("ckpt_corrupt"):
            # flip one payload byte past the .npy header — the exact
            # stand-in for bit rot / a torn copy on the real artifact
            pos = min(len(raw_bytes) - 1, 128)
            raw_bytes = (raw_bytes[:pos]
                         + bytes([raw_bytes[pos] ^ 0x01])
                         + raw_bytes[pos + 1:])
        want = entry.get("crc32")
        if want is not None:
            got = zlib.crc32(raw_bytes) & 0xFFFFFFFF
            if got != int(want):
                raise CheckpointCorruption(step, key, entry["file"],
                                           int(want), got)
        raw = np.load(io.BytesIO(raw_bytes))
        if entry.get("packed_boolean"):
            arr = _unpack_bool(raw, entry["shape"],
                               int(np.prod(entry["shape"])))
        elif entry.get("bf16_as_u16"):
            arr = raw.view(jax.numpy.bfloat16).reshape(entry["shape"])
        else:
            arr = raw.reshape(entry["shape"])
        sh = flat_sh.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr)

    missing = set(flat_tpl) - set(out)
    if missing:
        raise KeyError(f"checkpoint {src} missing leaves: {sorted(missing)[:5]}")
    # rebuild tree in template structure
    leaves_in_order = [out[k] for k in flat_tpl]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order), step


class CheckpointManager:
    """Keep-N async checkpointing with restore-latest; one in-flight write."""

    def __init__(self, directory, every: int = 100):
        self.directory = Path(directory)
        self.every = every
        self._inflight: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.every:
            return False
        self.wait()
        self._inflight = save_pytree(tree, self.directory, step)
        return True

    def save_now(self, step: int, tree):
        self.wait()
        save_pytree(tree, self.directory, step, sync=True)

    def wait(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def restore_latest(self, template, shardings=None, faults=None):
        return restore_pytree(template, self.directory,
                              shardings=shardings, faults=faults)

    def latest_step(self):
        return latest_step(self.directory)
