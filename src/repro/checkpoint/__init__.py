from .manager import (CheckpointCorruption, CheckpointManager, restore_pytree,
                      save_pytree)
